"""Engine perf trajectory: restitch, e2e sim, and device-overlap modes.

Three measurements, written to ``BENCH_engine.json`` at the repo root:

* (a) invoker arrivals/sec at queue depths {16, 64, 256} for the
  incremental packer (live ``PackState``, probe-then-append) vs the
  paper's literal from-scratch restitch of the whole queue per arrival.
  Arrivals use a huge SLO and an unbounded canvas budget so the queue
  actually reaches the target depth — this isolates restitch cost.
* (b) end-to-end simulated serving throughput (patches/sec) through the
  unified engine: bandwidth-shaped arrivals -> per-class invoker pool ->
  SimExecutor/platform, on the standard multi-camera synthetic streams.
* (c) device-overlap: sync vs async device mode arrivals/sec and p99
  latency on a bursty trace.  The "device" is the deterministic
  ``StubAccelerator`` (serial queue, fixed per-invocation service time —
  host never burns CPU for it, exactly like a real accelerator), while
  the host side is the real pipeline: crop gather, slot packing, stitch
  and unstitch dispatch, detection routing.  Sync blocks the event loop
  on every invocation; async (bounded in-flight) overlaps device service
  with arrival ingestion and restitching.
* (d) worker scaling: the same bursty trace served by a
  ``WorkerPoolExecutor`` over 1 / 2 / 4 workers, each worker its own
  ``StubAccelerator`` (independent serial device queue, the pool analogue
  of independent mesh slices) behind an async executor with a shared
  frame store.  Reports arrivals/sec and p99 added latency per pool
  size; the 4-vs-1 speedup is the acceptance number for multi-worker
  in-flight scheduling.

With ``--source synthetic`` a fifth arm measures live-source ingestion:
two synthetic cameras run the edge pipeline during serving, overloading
the sim platform through the engine's ingestion window, and the report
records throughput plus the drop/degrade accounting that bounds the
backlog.  The e2e and source arms embed ``ServeConfig.to_dict()`` /
``LatencyTable.to_dict()`` so each measurement carries the exact
(rebuildable) scheduler configuration.

With ``--fleet`` a sixth arm measures fleet-scale sharding: 1k- and
10k-camera ``FleetCameraSource`` fleets (heterogeneous id-correlated
lognormal rates, diurnal + burst modulation) served by a single stock
engine vs a ``ShardedEngine`` at each shard count, planner layouts from
``FleetPlanner`` with blocked-LPT camera grouping.  Reports
arrivals/sec, p99, and violation rate per shard count, the best speedup
achieved at a no-worse violation rate (the 10k-camera arm is the
headline: the baseline burns its cycles in the O(classes) timer scan),
and a planner-vs-equal-split comparison at a tight worker budget
(``planner_wins`` gate).

Usage:
    PYTHONPATH=src python -m benchmarks.bench_engine --fleet    # full
    PYTHONPATH=src python -m benchmarks.bench_engine --smoke --source synthetic --fleet  # CI
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core.invoker import SLOAwareInvoker
from repro.core.latency import LatencyTable, detector_latency_model
from repro.core.partitioning import Patch
from repro.core.scheduler import TangramScheduler
from repro.serverless.platform import Platform, PlatformConfig

DEPTHS = (16, 64, 256)
CANVAS = 256
SERVICE_S = 0.008        # stub device service time per invocation
OVERLAP_CANVAS = 128     # smaller canvas: host work ~ device service, so
                         # the overlap headroom is actually measurable
WORKER_SERVICE_S = 0.03  # worker-scaling stub service time: device-bound
                         # regime, so adding workers is what pays
WORKER_COUNTS = (1, 2, 4)


def _queue_patches(depth: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [Patch(0, 0, int(rng.integers(16, 96)), int(rng.integers(16, 96)),
                  t_gen=i * 1e-4, slo=1e9) for i in range(depth)]


def bench_restitch(depth: int, incremental: bool, budget_s: float) -> float:
    """Arrivals/sec while filling a queue to ``depth`` (no firing)."""
    table = LatencyTable({1: (1e-9, 0.0)})
    patches = _queue_patches(depth)
    reps = 0
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < budget_s or reps == 0:
        inv = SLOAwareInvoker(CANVAS, CANVAS, table,
                              max_canvases=1 << 30,
                              incremental=incremental)
        for p in patches:
            inv.on_patch(0.0, p)
        assert len(inv.queue) == depth
        reps += 1
    return depth * reps / (time.perf_counter() - t0)


def bench_e2e(n_cams: int, n_frames: int, per_frame: int = 6) -> dict:
    rng = np.random.default_rng(0)
    streams = []
    for cam in range(n_cams):
        patches = []
        for f in range(n_frames):
            t = f / 10.0
            for _ in range(rng.integers(1, per_frame + 1)):
                patches.append(Patch(0, 0, int(rng.integers(16, 160)),
                                     int(rng.integers(16, 160)),
                                     frame_id=f, camera_id=cam,
                                     t_gen=t, slo=1.0))
        streams.append(patches)
    table = detector_latency_model(CANVAS, CANVAS).build_table(16)
    sched = TangramScheduler(CANVAS, CANVAS, table,
                             Platform(table, PlatformConfig()))
    t0 = time.perf_counter()
    res = sched.run(streams, bandwidth_bps=20e6)
    dt = time.perf_counter() - t0
    # the config/latency records round-trip through JSON (named
    # references only), so a report is enough to rebuild the scheduler
    return {"patches": res.n_patches, "seconds": round(dt, 4),
            "patches_per_s": round(res.n_patches / dt, 1),
            "violation_rate": round(res.violation_rate, 4),
            "invocations": res.invocations,
            "config": sched.config.to_dict(),
            "latency": table.to_dict()}


def _burst_trace(canvas: int, n_bursts: int, per_burst: int, seed: int = 0):
    """Bursty arrivals: each burst is one frame's patches in a tight
    cluster, bursts spaced so the invoker timer fires one invocation per
    burst."""
    rng = np.random.default_rng(seed)
    frames, patches = {}, []
    for b in range(n_bursts):
        frames[b] = rng.uniform(0.0, 1.0, (canvas, 2 * canvas, 3)) \
            .astype(np.float32)
        t0 = 0.25 * b
        for j in range(per_burst):
            w = int(rng.integers(32, 96))
            h = int(rng.integers(32, 96))
            x0 = int(rng.integers(0, 2 * canvas - w))
            y0 = int(rng.integers(0, canvas - h))
            patches.append(Patch(x0, y0, x0 + w, y0 + h, frame_id=b,
                                 t_gen=round(t0 + 0.001 * j, 4), slo=0.1))
    return frames, sorted(patches, key=lambda p: p.t_gen)


def bench_device_overlap(smoke: bool) -> dict:
    """Sync vs async device mode on the bursty trace (wall-clock timed)."""
    from repro.core.devicestub import StubAccelerator
    from repro.core.engine import (AsyncDeviceExecutor, DeviceExecutor,
                                   ServingEngine, uniform_pool)
    from repro.data.video import Arrival

    n_bursts = 8 if smoke else 40
    per_burst = 8
    canvas = OVERLAP_CANVAS
    frames, patches = _burst_trace(canvas, n_bursts, per_burst)
    arrivals = [Arrival(p.t_gen, p, 0.0) for p in patches]
    table = LatencyTable({1: (1e-3, 0.0)})
    counts = {}
    for p in patches:
        counts[p.frame_id] = counts.get(p.frame_id, 0) + 1

    def run(mode):
        with StubAccelerator(SERVICE_S) as stub:
            kw = dict(sync=stub.sync)
            if mode == "async":
                dev = AsyncDeviceExecutor(stub.serve_fn, None, canvas,
                                          canvas, max_inflight=4, **kw)
            else:
                dev = DeviceExecutor(stub.serve_fn, None, canvas, canvas,
                                     **kw)
            for fid, px in frames.items():
                dev.add_frame(fid, px, counts.get(fid, 0))
            eng = ServingEngine(
                uniform_pool(canvas, canvas, table, max_canvases=64), dev)
            t0 = time.perf_counter()
            eng.run(arrivals)
            dt = time.perf_counter() - t0
        lats = sorted(o.latency for o in eng.outcomes)
        return {"arrivals_per_s": round(len(arrivals) / dt, 1),
                "seconds": round(dt, 4),
                "invocations": len(eng.invocations),
                "p99_latency_s": round(lats[int(0.99 * (len(lats) - 1))], 4),
                "inflight_high_water": eng.inflight_high_water}

    run("sync")                      # warm the jit caches for these shapes
    # best-of-2 per mode: wall-clock timings on shared CI hosts jitter,
    # and the fastest rep is the least-perturbed measurement of each mode
    sync = min((run("sync") for _ in range(2)),
               key=lambda r: r["seconds"])
    asyn = min((run("async") for _ in range(2)),
               key=lambda r: r["seconds"])
    assert sync["invocations"] == asyn["invocations"], \
        "overlap mode leaked into invocation boundaries"
    return {"trace": {"canvas": canvas, "bursts": n_bursts,
                      "per_burst": per_burst, "stub_service_s": SERVICE_S},
            "sync": sync, "async": asyn,
            "speedup": round(asyn["arrivals_per_s"]
                             / sync["arrivals_per_s"], 2),
            "p99_added_latency_s": round(asyn["p99_latency_s"]
                                         - sync["p99_latency_s"], 4)}


def bench_worker_scaling(smoke: bool) -> dict:
    """Worker-pool throughput on the bursty trace: 1 / 2 / 4 workers,
    each its own stub device queue, routed least-outstanding."""
    from repro.core.devicestub import StubAccelerator
    from repro.core.engine import (AsyncDeviceExecutor, ServingEngine,
                                   uniform_pool)
    from repro.core.workers import device_worker_pool
    from repro.data.video import Arrival

    n_bursts = 8 if smoke else 40
    per_burst = 8
    canvas = OVERLAP_CANVAS
    frames, patches = _burst_trace(canvas, n_bursts, per_burst)
    arrivals = [Arrival(p.t_gen, p, 0.0) for p in patches]
    table = LatencyTable({1: (1e-3, 0.0)})
    counts = {}
    for p in patches:
        counts[p.frame_id] = counts.get(p.frame_id, 0) + 1

    def run(n_workers):
        stubs = [StubAccelerator(WORKER_SERVICE_S) for _ in range(n_workers)]
        try:
            pool_exec = device_worker_pool(
                n_workers,
                lambda i: AsyncDeviceExecutor(
                    stubs[i].serve_fn, None, canvas, canvas,
                    max_inflight=4, sync=stubs[i].sync))
            for fid, px in frames.items():
                pool_exec.add_frame(fid, px, counts.get(fid, 0))
            eng = ServingEngine(
                uniform_pool(canvas, canvas, table, max_canvases=64),
                pool_exec)
            t0 = time.perf_counter()
            eng.run(arrivals)
            dt = time.perf_counter() - t0
        finally:
            for s in stubs:
                s.close()
        lats = sorted(o.latency for o in eng.outcomes)
        assert len(eng.outcomes) == len(arrivals)
        return {"workers": n_workers,
                "arrivals_per_s": round(len(arrivals) / dt, 1),
                "seconds": round(dt, 4),
                "invocations": len(eng.invocations),
                "p99_latency_s": round(lats[int(0.99 * (len(lats) - 1))], 4),
                "per_worker": pool_exec.worker_stats()}

    run(1)                           # warm the jit caches for these shapes
    # best-of-2 per pool size: wall-clock timings on shared CI hosts
    # jitter, and the fastest rep is the least-perturbed measurement
    by_workers = {}
    for n in WORKER_COUNTS:
        best = min((run(n) for _ in range(2)), key=lambda r: r["seconds"])
        by_workers[str(n)] = best
    invs = {r["invocations"] for r in by_workers.values()}
    assert len(invs) == 1, \
        "pool size leaked into invocation boundaries: %r" % invs
    w1, w4 = by_workers["1"], by_workers[str(WORKER_COUNTS[-1])]
    return {"trace": {"canvas": canvas, "bursts": n_bursts,
                      "per_burst": per_burst,
                      "stub_service_s": WORKER_SERVICE_S},
            "by_workers": by_workers,
            "speedup_4v1": round(w4["arrivals_per_s"]
                                 / w1["arrivals_per_s"], 2),
            "p99_added_latency_s": round(w4["p99_latency_s"]
                                         - w1["p99_latency_s"], 4)}


def bench_source_ingestion(smoke: bool) -> dict:
    """Live-source serving under sustained overload: two synthetic
    cameras at a burst-modulated frame rate feed the sim platform
    through the ingestion window; the cameras degrade RoI quality (and
    drop at 2x the window) so the backlog stays bounded."""
    from repro.core.config import ServeConfig
    from repro.core.latency import LatencyTable
    from repro.sources import RateProfile, make_source

    n_frames = 20 if smoke else 80
    window = 24
    # slow platform vs a fast camera clock: overload is structural
    table = LatencyTable({1: (0.20, 0.0), 2: (0.32, 0.0), 4: (0.5, 0.0)})
    config = ServeConfig(max_canvases=4, ingestion_window=window)
    sched = TangramScheduler(OVERLAP_CANVAS, OVERLAP_CANVAS, table,
                             Platform(table, PlatformConfig()),
                             config=config)
    source = make_source(
        "synthetic", n_cameras=2, n_frames=n_frames,
        canvas=OVERLAP_CANVAS, bandwidth_bps=200e6, warmup_s=0.3,
        overload="degrade",
        rate=RateProfile(fps=30.0, burst_prob=0.2, burst_factor=2.0,
                         diurnal_amplitude=0.3, diurnal_period_s=4.0))
    t0 = time.perf_counter()
    res = sched.serve_source(source, name="source-ingestion")
    dt = time.perf_counter() - t0
    src = res.summary()["source"]
    return {"frames": src["frames_total"],
            "patches": src["patches_emitted"],
            "dropped": src["frames_dropped"],
            "degraded": src["frames_degraded"],
            "backlog_high_water": src["backlog_high_water"],
            "ingestion_window": window,
            "seconds": round(dt, 4),
            "patches_per_s": round(src["patches_emitted"] / dt, 1),
            "violation_rate": round(res.violation_rate, 4),
            "config": config.to_dict()}


def bench_mixed_model(smoke: bool) -> dict:
    """Multi-tenant serving economics: two SLO classes on two models,
    model-affinity placement + per-model warm pools vs model-oblivious
    least-outstanding on identical platform capacity.

    Each of the two platform shards has exactly one instance, so where
    a batch lands decides which weights are resident: oblivious routing
    interleaves both models on both workers and pays a weight swap on
    nearly every switch, while ``placement="model"`` parks each model
    on its home worker and loads weights once.  Deterministic tables
    (sigma 0) keep the comparison exact.
    """
    from repro.core.config import ServeConfig
    from repro.core.models import ModelSpec, register_model

    register_model(ModelSpec(
        name="bench-fast", canvas_m=CANVAS, canvas_n=CANVAS,
        weight_bytes=2e9,
        table=LatencyTable({1: (0.04, 0.0), 4: (0.10, 0.0),
                            8: (0.16, 0.0)})))
    register_model(ModelSpec(
        name="bench-heavy", canvas_m=CANVAS, canvas_n=CANVAS,
        weight_bytes=8e9,
        table=LatencyTable({1: (0.25, 0.0), 4: (0.60, 0.0),
                            8: (1.00, 0.0)})))

    rng = np.random.default_rng(7)
    n_frames = 15 if smoke else 60
    streams = []
    for cam, slo in enumerate((0.5, 2.0)):
        patches = []
        for f in range(n_frames):
            t = f / 10.0
            for _ in range(int(rng.integers(1, 5))):
                patches.append(Patch(0, 0, int(rng.integers(16, 160)),
                                     int(rng.integers(16, 160)),
                                     frame_id=f, camera_id=cam,
                                     t_gen=t, slo=slo))
        streams.append(patches)

    def run(placement):
        cfg = ServeConfig(classify="slo", n_workers=2, placement=placement,
                          model_map={"0.5": "bench-fast",
                                     "2.0": "bench-heavy"})
        table = LatencyTable({1: (0.1, 0.0)})
        plat = Platform(table, PlatformConfig(max_instances=2, pre_warm=2,
                                              keep_alive_s=60.0,
                                              container_cold_s=0.25))
        sched = TangramScheduler(CANVAS, CANVAS, table, plat, config=cfg)
        res = sched.run(streams, bandwidth_bps=20e6)
        models = res.model_stats or {}
        return {"placement": placement,
                "violation_rate": round(res.violation_rate, 4),
                "cold_starts": sum(r.get("cold_starts", 0)
                                   for r in models.values()),
                "weight_loads": sum(r.get("weight_loads", 0)
                                    for r in models.values()),
                "load_seconds": round(sum(r.get("load_seconds", 0.0)
                                          for r in models.values()), 4),
                "models": models, "config": cfg.to_dict()}

    affinity = run("model")
    oblivious = run("least")
    aff_cold = affinity["cold_starts"] + affinity["weight_loads"]
    obl_cold = oblivious["cold_starts"] + oblivious["weight_loads"]
    return {"affinity": affinity, "oblivious": oblivious,
            "cold_plus_loads_saved": obl_cold - aff_cold,
            "affinity_wins": (aff_cold < obl_cold
                              and affinity["violation_rate"]
                              <= oblivious["violation_rate"])}


FLEET_GROUP = 8          # cameras per batching class: classify is
                         # (slo, camera_id // FLEET_GROUP)
FLEET_TABLE = {1: (0.05, 0.0), 2: (0.08, 0.0), 4: (0.12, 0.0),
               8: (0.2, 0.0)}   # deterministic: arms differ only in layout


def _fleet_classify(p):
    return (p.slo, p.camera_id // FLEET_GROUP)


def _fleet_row(outcomes, n_arrivals: int, dt: float) -> dict:
    lats = sorted(o.latency for o in outcomes)
    viol = sum(o.violated for o in outcomes)
    return {"arrivals_per_s": round(n_arrivals / dt, 1),
            "seconds": round(dt, 4),
            "violation_rate": round(viol / max(len(outcomes), 1), 4),
            "p99_latency_s": round(lats[int(0.99 * (len(lats) - 1))], 4)}


def _fleet_platform(table, instances: int, seed: int = 0) -> Platform:
    return Platform(table, PlatformConfig(
        max_instances=instances, pre_warm=instances, cold_start_s=0.0,
        keep_alive_s=1e9, seed=seed))


def _run_fleet_single(arrivals, table, budget: int) -> dict:
    """The baseline every shard count is measured against: today's one
    ServingEngine — stock O(classes)-scan pool, one platform holding the
    whole worker budget."""
    from repro.core.engine import ServingEngine, SimExecutor, uniform_pool

    eng = ServingEngine(
        uniform_pool(CANVAS, CANVAS, table, classify=_fleet_classify),
        SimExecutor(_fleet_platform(table, budget)))
    t0 = time.perf_counter()
    eng.run(arrivals)
    dt = time.perf_counter() - t0
    return _fleet_row(eng.outcomes, len(arrivals), dt)


def _run_fleet_plan(arrivals, table, plan) -> dict:
    """One ShardedEngine run under ``plan``: per-shard fleet pools
    (event-heap timers) over per-shard platform slices sized by the
    plan's worker allocation."""
    from repro.core.engine import ServingEngine, SimExecutor
    from repro.core.fleet import ShardedEngine, fleet_uniform_pool

    engines = []
    for s in range(plan.n_shards):
        w = max(plan.workers_of(s), 1)
        engines.append(ServingEngine(
            fleet_uniform_pool(CANVAS, CANVAS, table,
                               classify=_fleet_classify),
            SimExecutor(_fleet_platform(table, w, seed=s))))
    sharded = ShardedEngine(engines, plan.shard_of, plan=plan)
    t0 = time.perf_counter()
    sharded.run(arrivals)
    dt = time.perf_counter() - t0
    return _fleet_row(sharded.outcomes, len(arrivals), dt)


def bench_fleet(smoke: bool) -> dict:
    """Fleet-scale sharding: single-engine baseline vs ShardedEngine at
    increasing shard counts on heterogeneous (lognormal rate, diurnal +
    burst) synthetic camera fleets, plus a cost-planner vs equal-split
    layout comparison at a tight worker budget.

    The baseline's per-arrival cost grows with the fleet's *active*
    class count (the stock pool's O(classes) timer scan), so the
    sharded speedup widens with fleet size — the 10k-camera arm is the
    >= 10x acceptance measurement.  The shard-count sweep uses i.i.d.
    per-camera rates (every camera emits, so the full class population
    is live); the planner comparison re-draws the same fleet with
    *id-correlated* rates (``sorted_by_rate``: cameras numbered by
    site, busiest first) — the regime where a contiguous equal split
    piles the hot sites onto one shard."""
    from repro.core.fleet import (EqualSplitPlanner, FleetCostModel,
                                  FleetPlanner)
    from repro.sources import FleetCameraSource

    table = LatencyTable(FLEET_TABLE)
    cost = FleetCostModel(latency=table)
    # (cameras, duration_s, worker budget, shard counts)
    fleets = ([(200, 2.0, 32, (1, 4))] if smoke
              else [(1000, 6.0, 256, (1, 4, 8, 16, 32)),
                    (10000, 2.0, 1024, (8, 16, 32))])
    report = {"classify": f"(slo, camera_id // {FLEET_GROUP})",
              "camera_block": FLEET_GROUP, "fleets": {}}
    overall = 0.0
    for n_cams, dur, budget, shard_counts in fleets:
        src = FleetCameraSource(n_cameras=n_cams, duration_s=dur,
                                rate_sigma=1.2, seed=3)
        arrivals = src.arrivals()
        rates = src.camera_rates()
        class_rates = src.class_rates()
        base = _run_fleet_single(arrivals, table, budget)
        print(f"fleet {n_cams}: single {base['arrivals_per_s']}/s "
              f"viol {base['violation_rate']}")
        planner = FleetPlanner(cost, worker_budget=budget)
        entry = {"cameras": n_cams, "arrivals": len(arrivals),
                 "duration_s": dur, "worker_budget": budget,
                 "single_engine": base, "sharded": {}}
        best = 0.0
        for s in shard_counts:
            plan = planner.plan(rates, class_rates=class_rates,
                                classes_per_camera=2, n_shards=s,
                                camera_block=FLEET_GROUP)
            row = _run_fleet_plan(arrivals, table, plan)
            row["speedup"] = round(
                row["arrivals_per_s"] / base["arrivals_per_s"], 2)
            entry["sharded"][str(s)] = row
            if row["violation_rate"] <= base["violation_rate"]:
                best = max(best, row["speedup"])
            print(f"fleet {n_cams}: {s}-shard {row['arrivals_per_s']}/s "
                  f"({row['speedup']}x) viol {row['violation_rate']}")
        entry["max_speedup_at_no_worse_violation"] = best
        overall = max(overall, best)

        # the planner's case: the same fleet re-drawn with
        # id-correlated rates (busiest sites share low camera ids) at a
        # worker budget tight enough that a naive contiguous layout
        # saturates its hot shards
        hot_src = FleetCameraSource(n_cameras=n_cams, duration_s=dur,
                                    rate_sigma=1.2, sorted_by_rate=True,
                                    seed=3)
        hot_arrivals = hot_src.arrivals()
        hot_rates = hot_src.camera_rates()
        tight = max(budget // 4, 2 * len(shard_counts))
        s_cmp = shard_counts[-1] if smoke else 8
        p_plan = FleetPlanner(cost, worker_budget=tight).plan(
            hot_rates, class_rates=hot_src.class_rates(),
            classes_per_camera=2, n_shards=s_cmp,
            camera_block=FLEET_GROUP)
        e_plan = EqualSplitPlanner(cost, worker_budget=tight).plan(
            hot_rates, n_shards=s_cmp)
        p_row = _run_fleet_plan(hot_arrivals, table, p_plan)
        e_row = _run_fleet_plan(hot_arrivals, table, e_plan)
        entry["planner_vs_equal"] = {
            "worker_budget": tight, "shards": s_cmp,
            "sorted_by_rate": True,
            "planner": p_row, "equal_split": e_row,
            "planner_wins": (p_row["violation_rate"]
                             <= e_row["violation_rate"])}
        print(f"fleet {n_cams}: planner viol {p_row['violation_rate']} "
              f"vs equal-split {e_row['violation_rate']} "
              f"at budget {tight}")
        report["fleets"][str(n_cams)] = entry
    report["max_speedup_at_no_worse_violation"] = overall
    return report


class _PacedSimExecutor:
    """SimExecutor whose submit also *waits* a fixed wall pace.

    The sim platform answers instantly, so a pure-sim fleet measures
    only router/scheduler Python time — which the GIL serializes no
    matter how many shard threads run.  Real shard loops spend most of
    their wall time in GIL-releasing waits (jit/Pallas dispatch, wall
    clock sleeps); ``time.sleep`` here is the faithful stand-in, so the
    sequential arm pays ``pace_s`` per invocation end-to-end while the
    parallel arm overlaps the waits across shard threads.  Engine-time
    transcripts are untouched (the sleep happens outside virtual time),
    so both arms must agree event-for-event.
    """

    def __init__(self, platform, pace_s: float):
        from repro.core.engine import SimExecutor
        self._inner = SimExecutor(platform)
        self.platform = platform
        self.pace_s = pace_s

    def submit(self, inv):
        handle = self._inner.submit(inv)
        time.sleep(self.pace_s)
        return handle

    def resolve(self, handle):
        return self._inner.resolve(handle)


def _outcome_key(o):
    p = o.patch
    return (p.camera_id, p.frame_id, p.x0, p.y0,
            round(o.t_arrive, 9), round(o.t_submit, 9),
            round(o.t_finish, 9))


def _fleet_paced_engines(plan, table, pace_s):
    from repro.core.engine import ServingEngine
    from repro.core.fleet import fleet_uniform_pool

    engines = []
    for s in range(plan.n_shards):
        w = max(plan.workers_of(s), 1)
        engines.append(ServingEngine(
            fleet_uniform_pool(CANVAS, CANVAS, table,
                               classify=_fleet_classify),
            _PacedSimExecutor(_fleet_platform(table, w, seed=s),
                              pace_s=pace_s)))
    return engines


def bench_fleet_parallel(smoke: bool) -> dict:
    """Parallel shard threads vs the sequential ShardedEngine.

    Identical fleet, plan, and per-shard engines on both arms; the only
    difference is whether the shard loops run on one thread or eight.
    Per-invocation wall pace (see :class:`_PacedSimExecutor`) models
    the GIL-releasing device dispatch a real deployment overlaps.
    Reported: arrivals/sec both arms, the speedup, violation-rate
    equality, and whether the merged outcome transcripts are identical
    (the determinism acceptance check, here under wall measurement)."""
    from repro.core.fleet import (FleetCostModel, FleetPlanner,
                                  ShardedEngine)
    from repro.core.parallel import ParallelShardedEngine
    from repro.sources import FleetCameraSource

    table = LatencyTable(FLEET_TABLE)
    n_cams, dur, pace_s = ((128, 1.0, 0.003) if smoke
                           else (512, 2.0, 0.002))
    shards = 8
    src = FleetCameraSource(n_cameras=n_cams, duration_s=dur,
                            rate_sigma=1.2, seed=3)
    arrivals = src.arrivals()
    plan = FleetPlanner(FleetCostModel(latency=table),
                        worker_budget=max(2 * shards, 16)).plan(
        src.camera_rates(), class_rates=src.class_rates(),
        classes_per_camera=2, n_shards=shards,
        camera_block=FLEET_GROUP)

    seq = ShardedEngine(_fleet_paced_engines(plan, table, pace_s),
                        plan.shard_of, plan=plan)
    t0 = time.perf_counter()
    seq.run(arrivals)
    seq_dt = time.perf_counter() - t0
    seq_row = _fleet_row(seq.outcomes, len(arrivals), seq_dt)

    par = ParallelShardedEngine(_fleet_paced_engines(plan, table, pace_s),
                                plan.shard_of, plan=plan)
    t0 = time.perf_counter()
    par.run(arrivals)
    par_dt = time.perf_counter() - t0
    par_row = _fleet_row(par.outcomes, len(arrivals), par_dt)

    return {
        "cameras": n_cams, "arrivals": len(arrivals), "duration_s": dur,
        "shards": shards, "pace_s": pace_s,
        "sequential": seq_row, "parallel": par_row,
        "speedup": round(par_row["arrivals_per_s"]
                         / max(seq_row["arrivals_per_s"], 1e-9), 2),
        "equal_violation_rate": (par_row["violation_rate"]
                                 == seq_row["violation_rate"]),
        "transcripts_identical": (
            [_outcome_key(o) for o in seq.outcomes]
            == [_outcome_key(o) for o in par.outcomes]),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short budgets for CI")
    ap.add_argument("--fleet", action="store_true",
                    help="additionally measure fleet-scale sharding "
                         "(ShardedEngine vs single engine, planner vs "
                         "equal split)")
    ap.add_argument("--parallel", action="store_true",
                    help="with --fleet: additionally measure the "
                         "per-shard-thread ParallelShardedEngine against "
                         "the sequential sharded engine (paced executors "
                         "model GIL-releasing device dispatch)")
    ap.add_argument("--source", choices=("trace", "synthetic"),
                    default="trace",
                    help="synthetic: additionally measure live-source "
                         "ingestion under overload (drop/degrade "
                         "accounting)")
    ap.add_argument("--out", default=None,
                    help="output path (default: repo-root BENCH_engine.json)")
    args = ap.parse_args(argv)

    budget = 0.2 if args.smoke else 1.0
    report = {"smoke": bool(args.smoke), "queue_restitch": {}}
    for depth in DEPTHS:
        inc = bench_restitch(depth, incremental=True, budget_s=budget)
        scr = bench_restitch(depth, incremental=False, budget_s=budget)
        report["queue_restitch"][str(depth)] = {
            "incremental_arrivals_per_s": round(inc, 1),
            "scratch_arrivals_per_s": round(scr, 1),
            "speedup": round(inc / scr, 2),
        }
        print(f"depth {depth:4d}: incremental {inc:10.0f}/s "
              f"scratch {scr:10.0f}/s  speedup {inc / scr:6.1f}x")

    report["e2e_sim"] = bench_e2e(n_cams=2 if args.smoke else 4,
                                  n_frames=15 if args.smoke else 40)
    print("e2e:", report["e2e_sim"])

    report["device_overlap"] = bench_device_overlap(args.smoke)
    ov = report["device_overlap"]
    print(f"device overlap: sync {ov['sync']['arrivals_per_s']}/s "
          f"async {ov['async']['arrivals_per_s']}/s "
          f"speedup {ov['speedup']}x "
          f"(p99 added {ov['p99_added_latency_s']}s, "
          f"in-flight high water {ov['async']['inflight_high_water']})")

    if args.source == "synthetic":
        report["source_ingestion"] = bench_source_ingestion(args.smoke)
        si = report["source_ingestion"]
        print(f"source ingestion: {si['patches']} patches from "
              f"{si['frames']} frames at {si['patches_per_s']}/s "
              f"({si['dropped']} dropped, {si['degraded']} degraded, "
              f"backlog high water {si['backlog_high_water']}/"
              f"{si['ingestion_window']})")

    report["mixed_model"] = bench_mixed_model(args.smoke)
    mm = report["mixed_model"]
    print(f"mixed model: affinity {mm['affinity']['weight_loads']} loads / "
          f"{mm['affinity']['cold_starts']} colds at "
          f"{mm['affinity']['violation_rate']} violations vs oblivious "
          f"{mm['oblivious']['weight_loads']} loads / "
          f"{mm['oblivious']['cold_starts']} colds at "
          f"{mm['oblivious']['violation_rate']} "
          f"(saved {mm['cold_plus_loads_saved']}, "
          f"wins={mm['affinity_wins']})")

    if args.fleet:
        report["fleet"] = bench_fleet(args.smoke)
        fl = report["fleet"]
        print(f"fleet sharding: max speedup "
              f"{fl['max_speedup_at_no_worse_violation']}x at no worse "
              f"violation rate")

    if args.fleet and args.parallel:
        report["fleet_parallel"] = bench_fleet_parallel(args.smoke)
        fp = report["fleet_parallel"]
        print(f"fleet parallel: seq "
              f"{fp['sequential']['arrivals_per_s']}/s vs parallel "
              f"{fp['parallel']['arrivals_per_s']}/s at {fp['shards']} "
              f"shards -> {fp['speedup']}x "
              f"(equal violation rate: {fp['equal_violation_rate']}, "
              f"transcripts identical: {fp['transcripts_identical']})")

    report["worker_scaling"] = bench_worker_scaling(args.smoke)
    ws = report["worker_scaling"]
    scaling = " ".join(
        f"{n}w {ws['by_workers'][str(n)]['arrivals_per_s']}/s"
        for n in WORKER_COUNTS)
    print(f"worker scaling: {scaling} -> "
          f"{ws['speedup_4v1']}x at {WORKER_COUNTS[-1]} workers "
          f"(p99 added {ws['p99_added_latency_s']}s)")

    out = pathlib.Path(args.out) if args.out else (
        pathlib.Path(__file__).resolve().parent.parent / "BENCH_engine.json")
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}")
    return report


if __name__ == "__main__":
    main()
