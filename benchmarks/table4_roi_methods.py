"""Table IV: RoI extraction method comparison.

GMM (ours) vs frame differencing (the optical-flow stand-in: both detect
motion between consecutive frames) vs a coarse learned-proxy extractor
(downsampled intensity saliency — mimics the low recall of tiny detectors
on distant objects).  Reports: coverage without partitioning (RoI), with
Algorithm 1 (+Partition), and bandwidth share (BW Cons.).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import gmm, rois
from repro.core.partitioning import coverage, partition_host
from repro.data import video
from repro.data.synthetic import Scene, preset


def _frame_diff_masks(frames, threshold=0.08):
    prev = None
    for f in frames:
        mask = np.zeros_like(f, bool) if prev is None else \
            (np.abs(f - prev) > threshold)
        prev = f
        yield mask


def _saliency_masks(frames, threshold=0.75):
    # crude "tiny model" proxy: bright-region proposals at 1/4 resolution
    for f in frames:
        h, w = f.shape
        small = f[: h - h % 4, : w - w % 4].reshape(h // 4, 4, w // 4, 4)
        yield np.kron(small.mean((1, 3)) > threshold,
                      np.ones((4, 4), bool))[:h, :w]


def evaluate(method: str, n_scenes: int = 4, n_frames: int = 25):
    covs_roi, covs_part, bw = [], [], []
    for i in range(n_scenes):
        scene = Scene(preset(i, width=common.WIDTH, height=common.HEIGHT))
        frames, gts = [], []
        for t, frame, gt in scene.frames(n_frames):
            frames.append(np.asarray(frame))
            gts.append(gt)
        if method == "gmm":
            state = gmm.init_state(common.HEIGHT, common.WIDTH)
            masks = []
            for f in frames:
                state, fg = gmm.update_jit(state, jnp.asarray(f))
                masks.append(np.asarray(fg))
        elif method == "frame_diff":
            masks = list(_frame_diff_masks(frames))
        else:
            masks = list(_saliency_masks(frames))
        patch_bytes = full_bytes = 0.0
        for k in range(10, len(frames)):       # skip warmup
            boxes, valid = rois.extract_rois_jit(jnp.asarray(masks[k]))
            b = np.asarray(boxes)[np.asarray(valid)]
            raw = [partition_host(np.array([bb]), common.WIDTH,
                                  common.HEIGHT, 1, 1)[0]
                   for bb in b] if len(b) else []
            covs_roi.append(coverage(raw, gts[k]))
            parts = partition_host(b, common.WIDTH, common.HEIGHT, 4, 4)
            covs_part.append(coverage(parts, gts[k]))
            patch_bytes += sum(video.patch_bytes(p) for p in parts)
            full_bytes += video.frame_bytes(common.WIDTH, common.HEIGHT)
        bw.append(100 * patch_bytes / full_bytes)
    return (float(np.mean(covs_roi)), float(np.mean(covs_part)),
            float(np.mean(bw)))


def run():
    return {m: evaluate(m) for m in ("gmm", "frame_diff", "saliency")}


def main():
    rows, us = common.timed(run)
    print("method,roi_coverage,partition_coverage,bw_pct")
    for m, (roi, part, bw) in rows.items():
        print(f"{m},{roi:.3f},{part:.3f},{bw:.1f}")
    # the paper's conclusion: +Partition improves every extractor
    gains = [rows[m][1] - rows[m][0] for m in rows]
    common.emit("table4_roi_methods", us,
                f"partition_gain_min={min(gains):.3f}")


if __name__ == "__main__":
    main()
