"""Shared benchmark scaffolding.

All paper-table benchmarks run the REAL edge pipeline (GMM -> RoI ->
Algorithm 1) on the ten synthetic scenes at 1/8 of 4K (480x270; canvas
scales 1024 -> 128 accordingly) and feed the same patch streams to every
policy.  Results are deterministic (seeded scenes, seeded platform).
"""
from __future__ import annotations

import functools
import sys
import time

import jax.numpy as jnp
import numpy as np

from repro.core import gmm, partitioning, rois
from repro.core.baselines import FrameMeta
from repro.core.latency import detector_latency_model
from repro.core.partitioning import Patch
from repro.data.synthetic import SCENE_PRESETS, Scene, preset

WIDTH, HEIGHT = 480, 272          # 1/8 of 4K (rounded to /16)
CANVAS = 128                      # 1024 * (480/3840)
N_FRAMES = 30
WARMUP_S = 1.0
FPS = 10.0
SLO = 1.0
N_SCENES = len(SCENE_PRESETS)

# The spatial 1/8 downscale shrinks bytes and compute by ~64x; to keep the
# simulation in the paper's operating regime (uplinks that can saturate,
# inference that pressures the SLO) the schedulers see bandwidth scaled
# accordingly, and canvas latency is modelled at the production 1024^2
# canvas on a 1-chip function slice.  The scale is half the raw area ratio
# because the GMM/zone pipeline on the synthetic scenes covers ~2-3x more
# frame area per patch than PANDA RoIs (box quantization at 1/8 res) —
# 20 Mbps should be feasible-but-pressured, as in Fig. 12.
AREA_SCALE = (3840 * 2160) / (WIDTH * HEIGHT) / 2.0


def sim_bandwidth(nominal_bps: float) -> float:
    """Nominal (paper-label) bandwidth -> simulated-scale bandwidth."""
    return nominal_bps / AREA_SCALE


ROI_CFG = rois.RoIConfig(downsample=4, dilate=1, max_rois=64, min_area=2)


@functools.lru_cache(maxsize=None)
def scene_pipeline(scene_idx: int, zone_x: int = 4, zone_y: int = 4,
                   n_frames: int = N_FRAMES, slo: float = SLO,
                   clamp_canvas: bool = True):
    """Run GMM -> RoIs -> Alg.1 for one scene.

    Returns (patches, frame_metas, gt_by_frame, stats) where stats carries
    per-frame RoI proportions and patch counts.  ``clamp_canvas`` caps
    patch extents at the canvas (scheduler paths); coverage studies pass
    False to evaluate the raw Algorithm-1 output.
    """
    scene = Scene(preset(scene_idx, width=WIDTH, height=HEIGHT, fps=FPS))
    state = gmm.init_state(HEIGHT, WIDTH)
    patches, metas, gt_by_frame = [], [], {}
    roi_props, patch_counts = [], []
    extract = lambda m: rois.extract_rois(m, ROI_CFG)
    import jax as _jax
    extract = _jax.jit(extract)
    for t, frame, gt in scene.frames(n_frames):
        state, fg = gmm.update_jit(state, jnp.asarray(frame))
        if t < WARMUP_S:
            continue
        boxes, valid = extract(jnp.asarray(fg))
        b = np.asarray(boxes)[np.asarray(valid)]
        ps = partitioning.partition_host(
            b, WIDTH, HEIGHT, zone_x, zone_y, frame_id=scene.t,
            camera_id=scene_idx, t_gen=t, slo=slo)
        if clamp_canvas:
            # cap patch extents at the canvas (zones can exceed it at
            # coarse grids; the scheduler validates this in production)
            ps = [Patch(p.x0, p.y0, min(p.x1, p.x0 + CANVAS),
                        min(p.y1, p.y0 + CANVAS), p.frame_id, p.camera_id,
                        p.t_gen, p.slo) for p in ps]
        patches.extend(ps)
        gt_area = int(((gt[:, 2] - gt[:, 0]) *
                       (gt[:, 3] - gt[:, 1])).sum()) if len(gt) else 0
        metas.append(FrameMeta(WIDTH, HEIGHT, gt_area, t_gen=t, slo=slo,
                               camera_id=scene_idx))
        gt_by_frame[scene.t] = gt
        roi_props.append(gt_area / (WIDTH * HEIGHT))
        patch_counts.append(len(ps))
    stats = {"roi_props": roi_props, "patch_counts": patch_counts}
    return patches, metas, gt_by_frame, stats


def canvas_latency_table(max_batch: int = 16):
    # production canvas (1024^2) on a single-chip function slice
    return detector_latency_model(1024, 1024, chips=1,
                                  overhead_s=0.012).build_table(max_batch)


def fullframe_latency_table():
    # full 4K frame as one input on the same slice (Masked/Full baselines)
    return detector_latency_model(2176, 3840, chips=1,
                                  overhead_s=0.012).build_table(4)


def emit(name: str, us_per_call: float, derived):
    """CSV contract for benchmarks/run.py: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6
