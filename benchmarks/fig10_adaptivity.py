"""Fig. 10: adaptivity — patches per frame and canvas-efficiency CDF."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.scheduler import TangramScheduler
from repro.serverless.platform import Platform, PlatformConfig
from repro.data.synthetic import SCENE_PRESETS


def run():
    table = common.canvas_latency_table()
    counts, effs = {}, []
    for i, (name, *_r) in enumerate(SCENE_PRESETS):
        patches, _, _, stats = common.scene_pipeline(i)
        counts[name] = (float(np.mean(stats["patch_counts"])),
                        int(np.max(stats["patch_counts"])))
        res = TangramScheduler(common.CANVAS, common.CANVAS, table,
                               Platform(table, PlatformConfig())).run(
            [patches], common.sim_bandwidth(40e6))
        effs.extend(res.canvas_efficiencies)
    cdf = {q: float(np.percentile(effs, q)) for q in (10, 25, 50, 75, 90)}
    return counts, cdf


def main():
    (counts, cdf), us = common.timed(run)
    print("scene,mean_patches_per_frame,max_patches_per_frame")
    for name, (mean, mx) in counts.items():
        print(f"{name},{mean:.2f},{mx}")
    print("canvas_eff_cdf," +
          ",".join(f"p{q}={v:.3f}" for q, v in cdf.items()))
    common.emit("fig10_adaptivity", us, f"median_canvas_eff={cdf[50]:.3f}")


if __name__ == "__main__":
    main()
