"""Table I: redundancy in video inference data, per scene.

RoI proportion = ground-truth object area / frame area (paper: 2.6-14.2%).
Redundancy = share of inference compute spent on non-RoI pixels when the
full frame is processed (paper: 9-15%): estimated as the non-RoI share of
patch-token compute relative to full-frame tokens.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.data.synthetic import SCENE_PRESETS


def run():
    rows = []
    for i, (name, *_rest) in enumerate(SCENE_PRESETS):
        patches, metas, gt, stats = common.scene_pipeline(i)
        roi_prop = float(np.mean(stats["roi_props"])) * 100
        patch_area = sum(p.area for p in patches)
        frame_area = common.WIDTH * common.HEIGHT * len(metas)
        # patches cover RoIs + alignment slack: the non-RoI share of the
        # *patch* compute is the irreducible redundancy of RoI serving
        gt_area = sum(m.fg_area for m in metas)
        redundancy = 100 * max(patch_area - gt_area, 0) / max(patch_area, 1)
        rows.append((name, len(metas), roi_prop, redundancy))
    return rows


def main():
    rows, us = common.timed(run)
    print("scene,frames,roi_prop_pct,redundancy_pct")
    for name, frames, prop, red in rows:
        print(f"{name},{frames},{prop:.2f},{red:.2f}")
    mean_prop = np.mean([r[2] for r in rows])
    common.emit("table1_redundancy", us, f"mean_roi_prop_pct={mean_prop:.2f}")


if __name__ == "__main__":
    main()
