"""Fig. 13: canvas efficiency under different bandwidth / SLO settings.

Paper: efficiency grows with both SLO (more time to wait for stitchable
patches) and bandwidth (faster arrivals give the solver more choices).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.scheduler import TangramScheduler
from repro.serverless.platform import Platform, PlatformConfig


def _effs(bw, slo, n_scenes=4):
    table = common.canvas_latency_table()
    effs = []
    for i in range(n_scenes):
        patches, _, _, _ = common.scene_pipeline(i, slo=slo)
        patches = [p.__class__(p.x0, p.y0, p.x1, p.y1, p.frame_id,
                               p.camera_id, p.t_gen, slo) for p in patches]
        res = TangramScheduler(common.CANVAS, common.CANVAS, table,
                               Platform(table, PlatformConfig())).run(
            [patches], common.sim_bandwidth(bw))
        effs.extend(res.canvas_efficiencies)
    return effs


def run():
    by_slo = {slo: _effs(40e6, slo) for slo in (0.5, 1.0, 1.5)}
    by_bw = {bw: _effs(bw, 1.0) for bw in (20e6, 40e6, 80e6)}
    return by_slo, by_bw


def main():
    (by_slo, by_bw), us = common.timed(run)
    print("dimension,setting,mean_eff,p50_eff,frac_above_60pct")
    for slo, effs in by_slo.items():
        e = np.asarray(effs)
        print(f"slo,{slo},{e.mean():.3f},{np.median(e):.3f},"
              f"{(e > 0.6).mean():.3f}")
    for bw, effs in by_bw.items():
        e = np.asarray(effs)
        print(f"bw_mbps,{bw/1e6:.0f},{e.mean():.3f},{np.median(e):.3f},"
              f"{(e > 0.6).mean():.3f}")
    slo_means = [np.mean(by_slo[s]) for s in sorted(by_slo)]
    common.emit("fig13_canvas_eff", us,
                f"eff_slo_trend={slo_means[0]:.3f}->{slo_means[-1]:.3f}")


if __name__ == "__main__":
    main()
