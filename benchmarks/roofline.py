"""§Roofline report: reads the dry-run JSON and prints the per-cell terms.

The dry-run itself (launch/dryrun.py) needs the 512-device world and runs
separately:
    PYTHONPATH=src python -m repro.launch.dryrun --all \
        --json out/dryrun_single_pod.json
This module is the analysis/reporting half and runs in the 1-device bench
world.  Also times a kernel microbench triple (interpret mode) so run.py
has a wall-clock component.
"""
from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "out",
                         "dryrun_single_pod.json")


def load(path=JSON_PATH):
    if not os.path.exists(path):
        return None
    return json.load(open(path))


def print_table(data):
    cols = ("arch", "shape", "bottleneck", "t_compute_s", "t_memory_s",
            "t_collective_s", "useful_flops_ratio", "roofline_fraction")
    print(",".join(cols))
    for r in data["results"]:
        print(",".join(str(r[c]) for c in cols))
    worst = min(data["results"],
                key=lambda r: float(r["roofline_fraction"]))
    coll = [r for r in data["results"] if r["bottleneck"] == "collective"]
    return worst, coll


def kernel_microbench():
    """Interpret-mode kernel timings (CPU correctness path, not TPU perf)."""
    from repro.kernels.attention import ops as aops
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    t0 = time.perf_counter()
    aops.flash_attention(q, k, k, causal=True, block_q=128, block_kv=128,
                         interpret=True).block_until_ready()
    return (time.perf_counter() - t0) * 1e6


def main():
    data = load()
    if data is None:
        common.emit("roofline", 0.0,
                    "missing out/dryrun_single_pod.json — run "
                    "repro.launch.dryrun --all first")
        return
    t0 = time.perf_counter()
    worst, coll = print_table(data)
    us = (time.perf_counter() - t0) * 1e6
    n_fit = sum(1 for r in data["results"] if r["fits_hbm"])
    common.emit(
        "roofline", us,
        f"cells={len(data['results'])} fits_hbm={n_fit} "
        f"worst_fraction={worst['arch']}x{worst['shape']}="
        f"{worst['roofline_fraction']} collective_bound={len(coll)}")


if __name__ == "__main__":
    main()
