"""§Roofline report + device hot-path kernel microbench suite.

Two halves:

* the legacy report (default, what ``benchmarks/run.py`` invokes): reads
  the dry-run JSON and prints the per-cell roofline terms.  The dry-run
  itself (launch/dryrun.py) needs the 512-device world and runs
  separately:
      PYTHONPATH=src python -m repro.launch.dryrun --all \
          --json out/dryrun_single_pod.json

* ``--kernels``: the device hot-path microbench (ROADMAP item 3).
  Times the three pipeline variants on one packer-built plan at canvas
  batch >= 8 — **unfused-fp** (stitch kernel -> jit detect -> unstitch
  kernel, the historical path), **fused** (stitch->patch-embed kernel ->
  trunk-from-tokens -> decode->gather kernel), and **fused-int8** (the
  fused path over int8-resident weights) — through
  ``core.latency.measure`` with its sync hook, so async dispatch never
  leaks out of the timed region.  Per-variant rows (mu/sigma,
  canvases/sec, end-to-end patches/sec, analytic stage-boundary bytes
  moved, resident weight bytes) land in ``BENCH_kernels.json`` at the
  repo root, next to ``BENCH_engine.json``.  Block shapes come from
  ``launch/hillclimb.py --cell kernel_blocks`` when that cell has run.

Usage:
    PYTHONPATH=src python -m benchmarks.roofline                # report
    PYTHONPATH=src python -m benchmarks.roofline --kernels --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "out",
                         "dryrun_single_pod.json")

KERNELS_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_kernels.json"


def load(path=JSON_PATH):
    if not os.path.exists(path):
        return None
    return json.load(open(path))


def print_table(data):
    cols = ("arch", "shape", "bottleneck", "t_compute_s", "t_memory_s",
            "t_collective_s", "useful_flops_ratio", "roofline_fraction")
    print(",".join(cols))
    for r in data["results"]:
        print(",".join(str(r[c]) for c in cols))
    worst = min(data["results"],
                key=lambda r: float(r["roofline_fraction"]))
    coll = [r for r in data["results"] if r["bottleneck"] == "collective"]
    return worst, coll


def kernel_microbench():
    """Interpret-mode kernel timings (CPU correctness path, not TPU perf)."""
    from repro.kernels.attention import ops as aops
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    t0 = time.perf_counter()
    aops.flash_attention(q, k, k, causal=True, block_q=128, block_kv=128,
                         interpret=True).block_until_ready()
    return (time.perf_counter() - t0) * 1e6


def _build_bench_plan(m: int, n: int, min_canvases: int, seed: int = 11):
    """A packer-built plan with at least ``min_canvases`` canvases, plus
    packed slot pixels — the shared input for every variant."""
    from repro.core.partitioning import Patch
    from repro.core.stitching import build_batch_plan, stitch
    from repro.kernels.stitch import ops as stitch_ops

    rng = np.random.default_rng(seed)
    patches = []
    while True:
        patches.append(Patch(0, 0, int(rng.integers(48, n // 2 + 33)),
                             int(rng.integers(48, m // 2 + 33)),
                             frame_id=len(patches) % 5))
        canvases = stitch(patches, m, n)
        if len(canvases) >= min_canvases:
            break
    plan = build_batch_plan(patches, canvases, m, n)
    crops = [np.asarray(rng.normal(size=(p.h, p.w, 3)), np.float32)
             for p in patches]
    slots = stitch_ops.pack_plan_host(crops, plan)
    return plan, slots


def _weight_nbytes(params) -> int:
    import jax
    return int(sum(np.asarray(l).nbytes
                   for l in jax.tree_util.tree_leaves(params)))


def kernel_suite(smoke: bool = False, out_path=None) -> dict:
    """The before/after microbench: unfused-fp vs fused vs fused-int8.

    All three variants run the *kernel* implementations (Pallas,
    interpret mode on CPU — identical code path to a TPU launch) over
    the same plan and weights, timed through ``core.latency.measure``
    with ``jax.block_until_ready`` as the sync hook.  Interpret-mode
    wall clocks measure kernel work on this host, not TPU performance;
    the analytic ``bytes_moved`` column (stage-boundary HBM traffic of
    the stitch/embed/decode/gather stages) is host-independent and is
    what the fused path is built to shrink.
    """
    import jax

    from repro.core.latency import measure
    from repro.kernels.stitch import ops as stitch_ops
    from repro.launch.serve import build_detector
    from repro.models import detector as detector_lib

    jax.devices()   # lock in the platform before any lazy heavy imports
    m = n = 128
    min_canvases = 8
    plan, slots_np = _build_bench_plan(m, n, min_canvases)
    bcount = plan.num_canvases
    slots = jnp.asarray(slots_np)
    records = jnp.asarray(plan.records)
    impl = "pallas_interpret"

    cfg_fp, params_fp, serve_fp, rules_fp = build_detector(canvas=m)
    cfg_q, params_q, serve_q, rules_q = build_detector(canvas=m,
                                                       quantize=True)
    patch = cfg_fp.patch
    side = m // patch
    seq = side * side
    d = cfg_fp.d_model

    try:
        from repro.launch.hillclimb import pick_block_rows
        block_rows = pick_block_rows(m, n, patch)
    except Exception:
        block_rows = None

    def tokens_fn(cfg, rules):
        return jax.jit(lambda p, t: detector_lib.forward_tokens(
            cfg, p, t, rules))

    tok_fp = tokens_fn(cfg_fp, rules_fp)
    tok_q = tokens_fn(cfg_q, rules_q)
    ek_fp, eb_fp = detector_lib.embed_params(cfg_fp, params_fp)
    ek_q, eb_q = detector_lib.embed_params(cfg_q, params_q)

    def unfused(_b):
        canvases = stitch_ops.stitch_canvases(slots, records, m, n,
                                              impl=impl)
        obj, boxes = serve_fp(params_fp, canvases)
        patch_out = stitch_ops.unstitch_patches(
            canvases, records, plan.slot_capacity, plan.hmax, plan.wmax,
            impl=impl)
        return obj, boxes, patch_out

    def fused(_b, _tok=None, _p=None, _ek=None, _eb=None):
        tokens = stitch_ops.stitch_embed(slots, records, _ek, _eb, m, n,
                                         patch, block_rows=block_rows,
                                         impl=impl)
        raw = _tok(_p, tokens)
        return stitch_ops.unstitch_decode(raw, records, patch,
                                          plan.slot_capacity, impl=impl)

    iters, warmup = (3, 1) if smoke else (10, 2)

    def run(fn):
        tbl = measure(fn, batch_sizes=(bcount,), iters=iters,
                      warmup=warmup, sync=jax.block_until_ready)
        return tbl.table[bcount]

    # analytic stage-boundary HBM traffic (f32): what crosses between
    # the stitch / detect-entry / detect-exit / gather stages.  The
    # trunk's internal traffic is identical across variants and
    # excluded; the weight column captures the int8 residency win.
    f32 = 4
    slot_bytes = plan.slot_capacity * plan.hmax * plan.wmax * 3 * f32
    canvas_bytes = bcount * m * n * 3 * f32
    token_bytes = bcount * seq * d * f32
    raw_bytes = bcount * side * side * 5 * f32
    grid_bytes = plan.slot_capacity * side * side * 5 * f32
    decoded_bytes = bcount * side * side * 5 * f32   # obj + 4 box coords
    unfused_bytes = (slot_bytes            # stitch reads slots
                     + canvas_bytes        # stitch writes canvases
                     + canvas_bytes        # patch-embed re-reads them
                     + decoded_bytes       # decode writes obj+boxes
                     + canvas_bytes        # unstitch re-reads canvases
                     + slot_bytes)         # unstitch writes patch slots
    fused_bytes = (slot_bytes              # fused stitch reads slots
                   + token_bytes           # ...and writes tokens directly
                   + raw_bytes             # decode+gather reads raw head
                   + grid_bytes)           # ...and writes slot grids

    rows = []
    for name, fn, wbytes, bytes_moved in (
            ("unfused-fp", unfused, _weight_nbytes(params_fp),
             unfused_bytes),
            ("fused",
             lambda b: fused(b, _tok=tok_fp, _p=params_fp, _ek=ek_fp,
                             _eb=eb_fp),
             _weight_nbytes(params_fp), fused_bytes),
            ("fused-int8",
             lambda b: fused(b, _tok=tok_q, _p=params_q, _ek=ek_q,
                             _eb=eb_q),
             _weight_nbytes(params_q), fused_bytes)):
        mu, sigma = run(fn)
        rows.append({
            "name": name, "canvas_batch": bcount,
            "patches": plan.num_patches,
            "mu_s": round(mu, 6), "sigma_s": round(sigma, 6),
            "canvases_per_s": round(bcount / mu, 1),
            "patches_per_s": round(plan.num_patches / mu, 1),
            "bytes_moved": int(bytes_moved),
            "weight_bytes": int(wbytes),
            "block_rows": block_rows,
        })
        print(f"{name:12s} mu={mu:.4f}s  {rows[-1]['canvases_per_s']:8.1f} "
              f"canvases/s  {rows[-1]['patches_per_s']:8.1f} patches/s  "
              f"{bytes_moved/1e6:6.2f} MB moved  "
              f"{wbytes/1e6:5.2f} MB weights")

    by = {r["name"]: r for r in rows}
    report = {
        "smoke": bool(smoke),
        "geometry": {"canvas_m": m, "canvas_n": n, "patch": patch,
                     "d_model": d, "canvas_batch": bcount,
                     "patches": plan.num_patches,
                     "slot_capacity": plan.slot_capacity,
                     "hmax": plan.hmax, "wmax": plan.wmax,
                     "impl": impl, "block_rows": block_rows},
        "rows": rows,
        "fused_speedup": round(by["unfused-fp"]["mu_s"]
                               / by["fused"]["mu_s"], 2),
        "bytes_reduction": round(1 - by["fused"]["bytes_moved"]
                                 / by["unfused-fp"]["bytes_moved"], 3),
        "int8_weight_reduction": round(
            1 - by["fused-int8"]["weight_bytes"]
            / by["unfused-fp"]["weight_bytes"], 3),
    }
    out = pathlib.Path(out_path) if out_path else KERNELS_JSON
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"fused speedup {report['fused_speedup']}x, bytes moved "
          f"-{100*report['bytes_reduction']:.0f}%, int8 weights "
          f"-{100*report['int8_weight_reduction']:.0f}%")
    print(f"wrote {out}")
    return report


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--kernels", action="store_true",
                   help="run the device hot-path kernel microbench and "
                        "write BENCH_kernels.json")
    p.add_argument("--smoke", action="store_true",
                   help="short budgets for CI")
    p.add_argument("--out", default=None,
                   help="kernel microbench output path (default: "
                        "repo-root BENCH_kernels.json)")
    # benchmarks/run.py calls main() with no argv: parse an empty list
    # so its own CLI filter words never leak into this parser
    args = p.parse_args([] if argv is None else argv)

    if args.kernels:
        t0 = time.perf_counter()
        report = kernel_suite(smoke=args.smoke, out_path=args.out)
        us = (time.perf_counter() - t0) * 1e6
        common.emit("roofline_kernels", us,
                    f"fused_speedup={report['fused_speedup']}x "
                    f"bytes_reduction={report['bytes_reduction']}")
        return

    data = load()
    if data is None:
        common.emit("roofline", 0.0,
                    "missing out/dryrun_single_pod.json — run "
                    "repro.launch.dryrun --all first")
        return
    t0 = time.perf_counter()
    worst, coll = print_table(data)
    us = (time.perf_counter() - t0) * 1e6
    n_fit = sum(1 for r in data["results"] if r["fits_hbm"])
    common.emit(
        "roofline", us,
        f"cells={len(data['results'])} fits_hbm={n_fit} "
        f"worst_fraction={worst['arch']}x{worst['shape']}="
        f"{worst['roofline_fraction']} collective_bound={len(coll)}")


if __name__ == "__main__":
    main(sys.argv[1:])
