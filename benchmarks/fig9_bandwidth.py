"""Fig. 9: bandwidth consumption — Tangram/ELF (patches) vs Masked vs Full.

Paper: patch transmission saves 10.5%-74.3% vs Full Frame across scenes.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.data import video
from repro.data.synthetic import SCENE_PRESETS


def run():
    rows = []
    for i, (name, *_r) in enumerate(SCENE_PRESETS):
        patches, metas, _, _ = common.scene_pipeline(i)
        patch_b = sum(video.patch_bytes(p) for p in patches)
        masked_b = sum(video.masked_frame_bytes(m.width, m.height, m.fg_area)
                       for m in metas)
        full_b = sum(video.frame_bytes(m.width, m.height) for m in metas)
        rows.append((name, patch_b / 1e6, masked_b / 1e6, full_b / 1e6,
                     100 * (1 - patch_b / full_b)))
    return rows


def main():
    rows, us = common.timed(run)
    print("scene,tangram_mb,masked_mb,full_mb,saving_vs_full_pct")
    for name, p, m, f, s in rows:
        print(f"{name},{p:.3f},{m:.3f},{f:.3f},{s:.1f}")
    savings = [r[4] for r in rows]
    common.emit("fig9_bandwidth", us,
                f"saving_range={min(savings):.1f}%..{max(savings):.1f}%")


if __name__ == "__main__":
    main()
