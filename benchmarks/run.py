"""Benchmark driver: one module per paper table/figure.

Each module prints its own CSV table plus one summary line in the
``name,us_per_call,derived`` contract.  Usage:
    PYTHONPATH=src python -m benchmarks.run          # everything
    PYTHONPATH=src python -m benchmarks.run table2   # substring filter
"""
from __future__ import annotations

import sys

from benchmarks import (fig8_cost, fig9_bandwidth, fig10_adaptivity,
                        fig12_e2e, fig13_canvas_eff, fig14_insight,
                        roofline, table1_redundancy, table2_bandwidth,
                        table3_accuracy, table4_roi_methods)

MODULES = [
    ("table1_redundancy", table1_redundancy),
    ("table2_bandwidth", table2_bandwidth),
    ("table3_accuracy", table3_accuracy),
    ("table4_roi_methods", table4_roi_methods),
    ("fig8_cost", fig8_cost),
    ("fig9_bandwidth", fig9_bandwidth),
    ("fig10_adaptivity", fig10_adaptivity),
    ("fig12_e2e", fig12_e2e),
    ("fig13_canvas_eff", fig13_canvas_eff),
    ("fig14_insight", fig14_insight),
    ("roofline", roofline),
]


def main() -> None:
    pattern = sys.argv[1] if len(sys.argv) > 1 else ""
    print("name,us_per_call,derived")
    for name, mod in MODULES:
        if pattern and pattern not in name:
            continue
        print(f"# --- {name} ---")
        mod.main()


if __name__ == "__main__":
    main()
