"""Table III: inference accuracy vs partition granularity.

Accuracy proxy: fraction of ground-truth objects fully covered by some
patch (a covered object is detectable by the downstream model; the paper
reports <=4%/5%/9% AP loss at 2x2/4x4/6x6 — finer grids lose objects that
straddle zone boundaries).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.partitioning import coverage, partition_host
from repro.data.synthetic import SCENE_PRESETS


def run():
    rows = []
    for i, (name, *_rest) in enumerate(SCENE_PRESETS):
        cells = []
        for grid in (1, 2, 4, 6):     # grid=1 ~ Full (everything covered)
            patches, metas, gt_by_frame, _ = common.scene_pipeline(
                i, zone_x=grid, zone_y=grid, clamp_canvas=False)
            by_frame = {}
            for p in patches:
                by_frame.setdefault(p.frame_id, []).append(p)
            covs = [coverage(by_frame.get(fid, []), gt)
                    for fid, gt in gt_by_frame.items()]
            cells.append(100 * float(np.mean(covs)) if covs else 0.0)
        rows.append((name, *cells))
    return rows


def main():
    rows, us = common.timed(run)
    print("scene,full_pct,grid2x2_pct,grid4x4_pct,grid6x6_pct")
    for name, full, g2, g4, g6 in rows:
        print(f"{name},{full:.1f},{g2:.1f},{g4:.1f},{g6:.1f}")
    drops = [np.mean([r[1] - r[k] for r in rows]) for k in (2, 3, 4)]
    common.emit("table3_accuracy", us,
                f"mean_coverage_drop_pct 2x2={drops[0]:.1f} "
                f"4x4={drops[1]:.1f} 6x6={drops[2]:.1f}")


if __name__ == "__main__":
    main()
