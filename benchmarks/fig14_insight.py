"""Fig. 14: batching insight — execution latency distribution, patches per
batch, amortized per-patch latency, transmission/execution breakdown.

Paper: higher bandwidth -> bigger batches -> larger per-batch latency but
LOWER amortized per-patch latency (0.0252 / 0.0223 / 0.0213 s at
20/40/80 Mbps, SLO = 1 s).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.scheduler import TangramScheduler
from repro.serverless.platform import Platform, PlatformConfig


def run():
    table = common.canvas_latency_table()
    out = {}
    for bw in (20e6, 40e6, 80e6):
        execs, ppb, results = [], [], []
        for i in range(4):
            patches, _, _, _ = common.scene_pipeline(i)
            plat = Platform(table, PlatformConfig())
            res = TangramScheduler(common.CANVAS, common.CANVAS, table,
                                   plat).run([patches], common.sim_bandwidth(bw))
            execs.extend(r.exec_s for r in plat.records)
            ppb.extend(res.patches_per_batch)
            results.append(res)
        out[bw] = {
            "exec_mean": float(np.mean(execs)),
            "exec_p99": float(np.percentile(execs, 99)),
            "patches_per_batch": float(np.mean(ppb)),
            "amortized": float(np.mean([r.amortized_latency
                                        for r in results])),
            "trans_s": float(np.sum([r.transmission_seconds
                                     for r in results])),
            "exec_s": float(np.sum([r.exec_seconds for r in results])),
        }
    return out


def main():
    out, us = common.timed(run)
    print("bw_mbps,exec_mean_s,exec_p99_s,patches_per_batch,"
          "amortized_s,total_trans_s,total_exec_s")
    for bw, r in out.items():
        print(f"{bw/1e6:.0f},{r['exec_mean']:.4f},{r['exec_p99']:.4f},"
              f"{r['patches_per_batch']:.2f},{r['amortized']:.4f},"
              f"{r['trans_s']:.2f},{r['exec_s']:.2f}")
    amort = [out[bw]["amortized"] for bw in sorted(out)]
    common.emit("fig14_insight", us,
                f"amortized_20/40/80={amort[0]:.4f}/{amort[1]:.4f}/"
                f"{amort[2]:.4f}")


if __name__ == "__main__":
    main()
