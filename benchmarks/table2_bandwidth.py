"""Table II: bandwidth consumption vs Full Frame at 2x2 / 4x4 / 6x6 zones.

Paper: finer grids save more bandwidth (19-95% of full frame across
scenes, decreasing with grid size).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.data import video
from repro.data.synthetic import SCENE_PRESETS


def run():
    rows = []
    for i, (name, *_rest) in enumerate(SCENE_PRESETS):
        cells = []
        for grid in (2, 4, 6):
            patches, metas, _, _ = common.scene_pipeline(i, zone_x=grid,
                                                         zone_y=grid)
            patch_b = sum(video.patch_bytes(p) for p in patches)
            full_b = sum(video.frame_bytes(m.width, m.height) for m in metas)
            cells.append(100 * patch_b / full_b)
        rows.append((name, *cells))
    return rows


def main():
    rows, us = common.timed(run)
    print("scene,grid2x2_pct,grid4x4_pct,grid6x6_pct")
    for name, g2, g4, g6 in rows:
        print(f"{name},{g2:.1f},{g4:.1f},{g6:.1f}")
    # finer grids must not use more bandwidth on average (paper claim)
    means = [np.mean([r[k] for r in rows]) for k in (1, 2, 3)]
    common.emit("table2_bandwidth", us,
                f"mean_pct 2x2={means[0]:.1f} 4x4={means[1]:.1f} "
                f"6x6={means[2]:.1f}")


if __name__ == "__main__":
    main()
