"""Fig. 8: serverless cost — Tangram vs ELF vs Masked Frame vs Full Frame.

Paper: Tangram cuts cost by 66.4% / 57.4% / 41.1% on average vs Masked,
Full, ELF respectively.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import baselines
from repro.core.scheduler import TangramScheduler
from repro.serverless.platform import Platform, PlatformConfig

BW = 40e6


def run(n_scenes: int = common.N_SCENES):
    table = common.canvas_latency_table()
    ftable = common.fullframe_latency_table()
    rows = []
    for i in range(n_scenes):
        patches, metas, _, _ = common.scene_pipeline(i)
        streams = [patches]
        t = TangramScheduler(common.CANVAS, common.CANVAS, table,
                             Platform(table, PlatformConfig())).run(
            streams, common.sim_bandwidth(BW), name="tangram")
        e = baselines.run_elf(streams, common.sim_bandwidth(BW),
                              Platform(table, PlatformConfig()),
                              common.CANVAS ** 2)
        m = baselines.run_frame_baseline([metas], common.sim_bandwidth(BW),
                                         Platform(ftable, PlatformConfig()),
                                         masked=True)
        f = baselines.run_frame_baseline([metas], common.sim_bandwidth(BW),
                                         Platform(ftable, PlatformConfig()),
                                         masked=False)
        rows.append((i, t.total_cost, e.total_cost, m.total_cost,
                     f.total_cost))
    return rows


def main():
    rows, us = common.timed(run)
    print("scene,tangram_usd,elf_usd,masked_usd,full_usd")
    for i, t, e, m, f in rows:
        print(f"{i},{t:.3e},{e:.3e},{m:.3e},{f:.3e}")
    t = np.mean([r[1] for r in rows])
    savings = {
        "vs_elf": 100 * (1 - t / np.mean([r[2] for r in rows])),
        "vs_masked": 100 * (1 - t / np.mean([r[3] for r in rows])),
        "vs_full": 100 * (1 - t / np.mean([r[4] for r in rows])),
    }
    common.emit("fig8_cost", us,
                " ".join(f"save_{k}={v:.1f}%" for k, v in savings.items()))


if __name__ == "__main__":
    main()
