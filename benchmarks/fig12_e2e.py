"""Fig. 12: end-to-end cost + SLO violation across bandwidth x SLO grid,
Tangram vs Clipper vs ELF vs MArk.

Paper: Tangram achieves the lowest cost at every (bw, SLO) cell and keeps
violations < 5% (savings up to 61.2% / 31.0% / 66.4% vs Clipper / ELF /
MArk at 20/40/80 Mbps).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import baselines
from repro.core.scheduler import TangramScheduler
from repro.serverless.platform import Platform, PlatformConfig

BWS = (20e6, 40e6, 80e6)
SLOS = (0.5, 1.0, 1.5)
N_SCENES = 4


def _streams(slo):
    streams = []
    for i in range(N_SCENES):
        patches, _, _, _ = common.scene_pipeline(i, slo=slo)
        streams.append([p.__class__(p.x0, p.y0, p.x1, p.y1, p.frame_id,
                                    p.camera_id, p.t_gen, slo)
                        for p in patches])
    return streams


def run():
    table = common.canvas_latency_table()
    area = common.CANVAS ** 2
    rows = []
    for bw in BWS:
        for slo in SLOS:
            streams = _streams(slo)
            t = TangramScheduler(common.CANVAS, common.CANVAS, table,
                                 Platform(table, PlatformConfig())).run(
                streams, common.sim_bandwidth(bw))
            # Clipper/MArk pad every patch to the worst-case tile (the
            # canvas: patches can reach canvas size) — the paper's
            # padding-overhead argument for uniform-input batching
            c = baselines.run_clipper(streams, common.sim_bandwidth(bw),
                                      Platform(table, PlatformConfig()),
                                      area, tile_side=common.CANVAS,
                                      slo=slo)
            e = baselines.run_elf(streams, common.sim_bandwidth(bw),
                                  Platform(table, PlatformConfig()), area)
            m = baselines.run_mark(streams, common.sim_bandwidth(bw),
                                   Platform(table, PlatformConfig()), area,
                                   tile_side=common.CANVAS,
                                   timeout=slo / 4)
            rows.append({
                "bw_mbps": bw / 1e6, "slo_s": slo,
                "tangram": (t.total_cost, t.violation_rate),
                "clipper": (c.total_cost, c.violation_rate),
                "elf": (e.total_cost, e.violation_rate),
                "mark": (m.total_cost, m.violation_rate),
            })
    return rows


def main():
    rows, us = common.timed(run)
    print("bw_mbps,slo_s,"
          "tangram_usd,tangram_viol,clipper_usd,clipper_viol,"
          "elf_usd,elf_viol,mark_usd,mark_viol")
    for r in rows:
        print(f"{r['bw_mbps']:.0f},{r['slo_s']},"
              f"{r['tangram'][0]:.3e},{r['tangram'][1]:.3f},"
              f"{r['clipper'][0]:.3e},{r['clipper'][1]:.3f},"
              f"{r['elf'][0]:.3e},{r['elf'][1]:.3f},"
              f"{r['mark'][0]:.3e},{r['mark'][1]:.3f}")
    viols = [r["tangram"][1] for r in rows]
    save = {}
    for base in ("clipper", "elf", "mark"):
        save[base] = 100 * max(1 - r["tangram"][0] / max(r[base][0], 1e-12)
                               for r in rows)
    common.emit("fig12_e2e", us,
                f"max_viol={max(viols):.3f} " +
                " ".join(f"max_save_vs_{k}={v:.1f}%"
                         for k, v in save.items()))


if __name__ == "__main__":
    main()
